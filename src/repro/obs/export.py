"""Exporters: Chrome-trace-event JSON (Perfetto-loadable) + metrics snapshot.

``chrome_trace`` renders a ``Tracer``'s spans and instants into the Chrome
trace-event format (the JSON flavour ``chrome://tracing`` and
https://ui.perfetto.dev load directly):

  * track names ``process/thread`` map to one pid per process group (a silo,
    ``link``, ``orchestrator``) and one tid per thread within it (``phases``,
    ``a~b/fg``, ...), named via ``"M"`` metadata events;
  * spans become ``"X"`` complete events — simulated seconds scaled to
    trace micros (``ts``/``dur``), span attrs under ``args``;
  * instants become thread-scoped ``"i"`` events.

Events are emitted sorted by (pid, tid, ts, -dur) so same-start nested spans
render parent-first and per-track timestamps are monotone — properties the
well-formedness tests (and ``validate_chrome_trace``) check.

``write_chrome_trace`` additionally embeds the flat metrics snapshot under a
top-level ``"metrics"`` key (extra top-level keys are legal in the format
and ignored by viewers).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

_US = 1e6  # simulated seconds -> trace microseconds


def _split_track(track: str) -> Tuple[str, str]:
    """``process/thread`` track naming; a bare name is its own process."""
    if "/" in track:
        proc, thread = track.split("/", 1)
        return proc or "-", thread or "main"
    return track or "-", "main"


def _clean_args(attrs: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[str(k)] = v
        else:
            out[str(k)] = str(v)
    return out


def chrome_trace(tracer, metrics: Optional[Dict[str, Any]] = None) -> Dict:
    """Render a Tracer into a Chrome trace-event document (dict)."""
    procs: Dict[str, int] = {}
    tids: Dict[Tuple[int, str], int] = {}

    def ids(track: str) -> Tuple[int, int]:
        proc, thread = _split_track(track)
        pid = procs.setdefault(proc, len(procs) + 1)
        key = (pid, thread)
        if key not in tids:
            tids[key] = sum(1 for (p, _) in tids if p == pid) + 1
        return pid, tids[key]

    # spans and instants share tracks (e.g. a recovery span on a silo's
    # chain track next to its seal/import instants), so they must be merged
    # into ONE per-track ordering: by ts, spans before instants at the same
    # ts, longest span first (parent-first nesting).
    rows: List[Tuple[Tuple[int, int], float, int, float, Dict[str, Any]]] = []
    for s in tracer.spans:
        pid, tid = ids(s.track)
        rows.append(((pid, tid), s.t0, 0, -(s.t1 - s.t0),
                     {"name": s.kind, "cat": s.kind.split(".", 1)[0],
                      "ph": "X", "ts": round(s.t0 * _US, 3),
                      "dur": round(max(0.0, s.t1 - s.t0) * _US, 3),
                      "pid": pid, "tid": tid, "args": _clean_args(s.attrs)}))
    for t, kind, track, attrs in tracer.events:
        pid, tid = ids(track)
        rows.append(((pid, tid), t, 1, 0.0,
                     {"name": kind, "cat": kind.split(".", 1)[0],
                      "ph": "i", "s": "t", "ts": round(t * _US, 3),
                      "pid": pid, "tid": tid, "args": _clean_args(attrs)}))
    rows.sort(key=lambda r: (r[0], r[1], r[2], r[3], r[4]["name"]))
    events = [r[4] for r in rows]

    meta: List[Dict[str, Any]] = []
    for proc, pid in sorted(procs.items(), key=lambda kv: kv[1]):
        meta.append({"name": "process_name", "ph": "M", "ts": 0, "pid": pid,
                     "tid": 0, "args": {"name": proc}})
    for (pid, thread), tid in sorted(tids.items(),
                                     key=lambda kv: (kv[0][0], kv[1])):
        meta.append({"name": "thread_name", "ph": "M", "ts": 0, "pid": pid,
                     "tid": tid, "args": {"name": thread}})

    doc: Dict[str, Any] = {"traceEvents": meta + events,
                           "displayTimeUnit": "ms",
                           "otherData": {"clock": "simulated-seconds*1e6"}}
    if metrics is not None:
        doc["metrics"] = metrics
    return doc


def write_chrome_trace(path: str, tracer,
                       metrics: Optional[Dict[str, Any]] = None) -> Dict:
    doc = chrome_trace(tracer, metrics=metrics)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return doc


# --------------------------------------------------------------------------- #
# Validation — shared by the tests, the report CLI and `make trace`.
# --------------------------------------------------------------------------- #

_REQUIRED = ("name", "ph", "pid", "tid", "ts")


def validate_chrome_trace(doc: Any) -> List[str]:
    """Structural validation of a trace-event document. Returns a list of
    problems — empty means the trace is well-formed: known phase types,
    required fields present, non-negative ``X`` durations, metadata naming
    every (pid, tid), and monotone timestamps per track."""
    problems: List[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["document is not a dict with a traceEvents list"]
    named_pids, named_tids = set(), set()
    used_pids, used_tids = set(), set()
    last_ts: Dict[Tuple[int, int], float] = {}
    for i, e in enumerate(doc["traceEvents"]):
        if not isinstance(e, dict):
            problems.append(f"event[{i}]: not an object")
            continue
        missing = [k for k in _REQUIRED if k not in e]
        if missing:
            problems.append(f"event[{i}]: missing fields {missing}")
            continue
        ph = e["ph"]
        if ph not in ("X", "i", "M"):
            problems.append(f"event[{i}]: unknown phase {ph!r}")
            continue
        if not isinstance(e["ts"], (int, float)):
            problems.append(f"event[{i}]: non-numeric ts")
            continue
        if ph == "M":
            if e["name"] == "process_name":
                named_pids.add(e["pid"])
            elif e["name"] == "thread_name":
                named_tids.add((e["pid"], e["tid"]))
            continue
        used_pids.add(e["pid"])
        used_tids.add((e["pid"], e["tid"]))
        key = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(key, float("-inf")):
            problems.append(f"event[{i}]: ts {e['ts']} not monotone on "
                            f"track pid={key[0]} tid={key[1]}")
        last_ts[key] = e["ts"]
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event[{i}]: X event with bad dur {dur!r}")
        if ph == "i" and e.get("s") not in ("t", "p", "g"):
            problems.append(f"event[{i}]: instant with bad scope "
                            f"{e.get('s')!r}")
    for pid in used_pids - named_pids:
        problems.append(f"pid {pid} has no process_name metadata")
    for key in used_tids - named_tids:
        problems.append(f"(pid,tid) {key} has no thread_name metadata")
    return problems
