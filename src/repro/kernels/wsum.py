"""Pallas kernel: weighted sum of M flattened models (FedAvg/aggregation).

out[n] = sum_m w[m] * x[m, n] — the hot loop of every aggregation policy once
the model set and weights are chosen. Streams N in VMEM tiles; one HBM pass
over M*N input elements, f32 accumulation regardless of storage dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 4096


def _kernel(w_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)       # [M, TILE_N]
    w = w_ref[...].astype(jnp.float32)       # [1, M]
    o_ref[...] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def weighted_sum(x, w, *, interpret: bool = False):
    """x: [M, N] (N % TILE_N == 0); w: [M] -> [N] in x.dtype."""
    M, N = x.shape
    assert N % TILE_N == 0, f"pad N to a multiple of {TILE_N}"
    grid = (N // TILE_N,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, M), lambda i: (0, 0)),
                  pl.BlockSpec((M, TILE_N), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, TILE_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), x.dtype),
        interpret=interpret,
    )(w[None, :], x)
    return out[0]
