"""Pallas kernel: chunked WKV6 recurrence (RWKV-6 "Finch" time-mix).

The per-(batch*head) recurrence
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
is evaluated in chunks of C tokens: within a chunk the strictly-causal part is
an [C, C] matmul against cumulative decay products (kept f32-safe for C = 32),
the cross-chunk part flows through a VMEM-resident state scratch [hs, hs] that
persists across the sequential chunk grid dimension.

Grid: (B*H, T/C) with the chunk index minor => chunks execute in order per
(batch, head) while the MXU sees [C, hs] x [hs, C] tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 32


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s1_ref, S):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        S[...] = s0_ref[0]

    rb = r_ref[0].astype(jnp.float32)  # [C, hs]
    kb = k_ref[0].astype(jnp.float32)
    vb = v_ref[0].astype(jnp.float32)
    wb = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # [hs]

    logw = jnp.log(jnp.clip(wb, 1e-6, 1.0))
    c_incl = jnp.cumsum(logw, axis=0)
    c_excl = c_incl - logw
    c_tot = c_incl[-1:]                # [1, hs]

    r_dec = rb * jnp.exp(c_excl)
    k_inv = kb * jnp.exp(-jnp.clip(c_incl, -25.0, 0.0))
    dot = lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    A = dot(r_dec, k_inv)              # [C, C]
    idx = jax.lax.broadcasted_iota(jnp.int32, (CHUNK, CHUNK), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (CHUNK, CHUNK), 1)
    A = jnp.where(idx > jdx, A, 0.0)
    y = jax.lax.dot_general(A, vb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    bonus = jnp.sum(rb * u[None, :] * kb, axis=1, keepdims=True)
    y += bonus * vb
    y += jax.lax.dot_general(r_dec, S[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    k_dec = kb * jnp.exp(c_tot - c_incl)
    S[...] = S[...] * jnp.exp(c_tot).T + jax.lax.dot_general(
        k_dec, vb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s1_ref[0] = S[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6(r, k, v, w, u, state, *, interpret: bool = False):
    """r,k,v,w: [BH, T, hs] (T % CHUNK == 0); u: [BH, hs];
    state: [BH, hs, hs] f32. Returns (y [BH,T,hs], state')."""
    BH, T, hs = r.shape
    assert T % CHUNK == 0, f"pad T to a multiple of {CHUNK}"
    grid = (BH, T // CHUNK)
    blk_seq = pl.BlockSpec((1, CHUNK, hs), lambda b, j: (b, j, 0))
    blk_state = pl.BlockSpec((1, hs, hs), lambda b, j: (b, 0, 0))
    blk_u = pl.BlockSpec((1, hs), lambda b, j: (b, 0))
    y, s1 = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[blk_seq, blk_seq, blk_seq, blk_seq, blk_u, blk_state],
        out_specs=[blk_seq, blk_state],
        out_shape=[jax.ShapeDtypeStruct((BH, T, hs), r.dtype),
                   jax.ShapeDtypeStruct((BH, hs, hs), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state.astype(jnp.float32))
    return y, s1
