"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def multikrum_dists(x):
    """x: [M, N] flattened models -> pairwise squared L2 [M, M] (f32)."""
    xf = x.astype(jnp.float32)
    sq = jnp.sum(xf * xf, axis=1)
    g = xf @ xf.T
    d = sq[:, None] + sq[None, :] - 2.0 * g
    return jnp.maximum(d, 0.0)


def multikrum_scores(x, m: int):
    """MultiKRUM score per model: sum of distances to its m nearest peers
    (lower = more central = better). x: [M, N]."""
    d = multikrum_dists(x)
    M = d.shape[0]
    d = d + jnp.diag(jnp.full((M,), jnp.inf))
    sorted_d = jnp.sort(d, axis=1)
    m = min(m, M - 1)
    return jnp.sum(sorted_d[:, :m], axis=1)


def weighted_sum(x, w):
    """x: [M, N] models, w: [M] weights -> [N] aggregate (f32 accumulate)."""
    return jnp.einsum("m,mn->n", w.astype(jnp.float32),
                      x.astype(jnp.float32)).astype(x.dtype)


def quantize_int8(x, tile: int = 1024):
    """Symmetric per-tile int8 quantization. x: [N] (N % tile == 0).
    Returns (q int8 [N], scales f32 [N/tile])."""
    xt = x.astype(jnp.float32).reshape(-1, tile)
    amax = jnp.max(jnp.abs(xt), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xt / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_int8(q, scales, tile: int = 1024):
    qt = q.reshape(-1, tile).astype(jnp.float32)
    return (qt * scales[:, None]).reshape(-1)


def add_q8_delta(base, q, scales, tile: int = 1024):
    """Oracle for the fused int8 delta-apply: materialize the dequantized f32
    delta (the copy the fused kernel avoids), then add. base: [n] (n <= Np),
    q: [Np] int8, scales: [Np/tile] -> [n] f32."""
    d = dequantize_int8(q, scales, tile)
    return base.astype(jnp.float32) + d[: base.shape[0]]


def dequantize_rows(q, scales, tile: int = 1024):
    """q: [M, N] int8, scales: [M, N/tile] -> [M, N] f32."""
    M, N = q.shape
    qt = q.reshape(M, N // tile, tile).astype(jnp.float32)
    return (qt * scales[:, :, None]).reshape(M, N)


def wsum_q8(q, scales, w, tile: int = 1024):
    """Oracle for the fused int8 weighted sum: dequantize, then weighted_sum.
    q: [M, N] int8, scales: [M, N/tile], w: [M] -> [N] f32."""
    x = dequantize_rows(q, scales, tile)
    return jnp.einsum("m,mn->n", w.astype(jnp.float32), x)


def gram_q8(q, scales, tile: int = 1024):
    """Oracle for the fused int8 Gram: dequantize, then X X^T + row norms.
    -> (G [M, M] f32, sq [M, 1] f32)."""
    x = dequantize_rows(q, scales, tile)
    return x @ x.T, jnp.sum(x * x, axis=1, keepdims=True)


def wkv6_naive(r, k, v, w, u, state):
    """Token-by-token WKV6 recurrence (oracle for the chunked kernel).

    r,k,v,w: [B, T, H, hs]; u: [H, hs]; state: [B, H, hs, hs].
    Returns (y [B,T,H,hs], state')."""
    B, T, H, hs = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs  # [B, H, hs]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhkv,bhk->bhv", S + uf[None, :, :, None] * kv, rt)
        S = S * wt[..., None] + kv
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    S, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), S
