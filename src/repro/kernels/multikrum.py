"""Pallas kernel: pairwise squared-L2 Gram accumulation for MultiKRUM scoring.

The paper's MultiKRUM scorer needs all-pairs distances between the M silo
models submitted in a round (M <= 64) whose flattened length N is huge
(62K for the paper's CNN, up to 1e11 for the assigned archs). The kernel
streams N in VMEM tiles, accumulating the Gram matrix G = X X^T and the
per-model squared norms; the [M, M] distance matrix falls out as
sq[i] + sq[j] - 2 G[ij].

Memory-bound: one pass over M*N elements; arithmetic intensity ~M flops/elem,
so for M >= 16 the MXU matmul tile keeps up with HBM easily.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 2048


def _kernel(x_ref, g_ref, sq_ref):
    """Grid step over N tiles. x_ref: [M, TILE_N]; accumulates G and sq."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    x = x_ref[...].astype(jnp.float32)
    g_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    sq_ref[...] += jnp.sum(x * x, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram_and_norms(x, *, interpret: bool = False):
    """x: [M, N] (N % TILE_N == 0) -> (G [M,M] f32, sq [M,1] f32)."""
    M, N = x.shape
    assert N % TILE_N == 0, f"pad N to a multiple of {TILE_N}"
    grid = (N // TILE_N,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((M, TILE_N), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((M, M), lambda i: (0, 0)),
                   pl.BlockSpec((M, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, M), jnp.float32),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)
