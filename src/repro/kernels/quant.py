"""Pallas kernels: symmetric per-tile int8 quantize/dequantize.

Beyond-paper optimization: silo models are int8-compressed before the
cross-silo exchange (IPFS put / pod-axis all-gather), cutting transfer bytes
4x (bf16) / 4x (f32->int8+scales). One VMEM pass each way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024
LANE = 128  # quantization tiles per VMEM block


def _q_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # [LANE, TILE]
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dq_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * s_ref[...]).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize(x, *, interpret: bool = False):
    """x: [N] (N % (TILE*LANE) == 0) -> (q int8 [N], scales f32 [N/TILE])."""
    N = x.shape[0]
    assert N % (TILE * LANE) == 0, f"pad N to a multiple of {TILE * LANE}"
    rows = N // TILE
    x2 = x.reshape(rows, TILE)
    grid = (rows // LANE,)
    q, s = pl.pallas_call(
        _q_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((LANE, TILE), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((LANE, TILE), lambda i: (i, 0)),
                   pl.BlockSpec((LANE, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, TILE), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x2)
    return q.reshape(-1), s[:, 0]


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize_batch(q, scales, *, dtype=jnp.float32, interpret: bool = False):
    """Batched dequantize: K packed payloads in ONE kernel launch.

    q: [K, N] int8 (N % (TILE*LANE) == 0); scales: [K, N/TILE] -> [K, N].
    Same VMEM block body as ``dequantize`` with the payload index as the
    major grid axis — the scoring engine ingests a whole round's q8 models
    without K separate dispatches (oracle: ``ref.dequantize_rows``)."""
    K, N = q.shape
    assert N % (TILE * LANE) == 0, f"pad N to a multiple of {TILE * LANE}"
    rows = N // TILE
    grid = (K, rows // LANE)
    x = pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, LANE, TILE), lambda k, i: (k, i, 0)),
                  pl.BlockSpec((1, LANE, 1), lambda k, i: (k, i, 0))],
        out_specs=pl.BlockSpec((1, LANE, TILE), lambda k, i: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, rows, TILE), dtype),
        interpret=interpret,
    )(q.reshape(K, rows, TILE), scales[:, :, None])
    return x.reshape(K, N)


@functools.partial(jax.jit, static_argnames=("dtype", "interpret"))
def dequantize(q, scales, *, dtype=jnp.float32, interpret: bool = False):
    N = q.shape[0]
    rows = N // TILE
    grid = (rows // LANE,)
    x = pl.pallas_call(
        _dq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((LANE, TILE), lambda i: (i, 0)),
                  pl.BlockSpec((LANE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((LANE, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, TILE), dtype),
        interpret=interpret,
    )(q.reshape(rows, TILE), scales[:, None])
    return x.reshape(-1)
