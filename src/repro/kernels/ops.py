"""Jit'd public wrappers over the Pallas kernels.

On TPU the pallas path compiles natively; elsewhere (this CPU container) the
same kernel body runs under ``interpret=True`` so numerics are identical and
every kernel is exercised end-to-end. ``force='ref'`` selects the pure-jnp
oracle (used by tests to cross-validate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import multikrum as _mk
from repro.kernels import q8agg as _q8
from repro.kernels import quant as _q
from repro.kernels import ref as _ref
from repro.kernels import rwkv6 as _rwkv
from repro.kernels import wsum as _ws


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis: int, multiple: int, value=0.0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# --------------------------------------------------------------------------- #
# Flatten helpers (model pytree <-> single vector)
# --------------------------------------------------------------------------- #

_SPEC_CACHE: dict = {}


def make_flatten_spec(params):
    """Derive (and cache) the flatten spec for a pytree's config: one spec per
    (structure, shapes, dtypes) — the round-critical path flattens/unflattens
    against it every round without re-deriving leaf metadata."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    key = (treedef, tuple((tuple(l.shape), np.dtype(l.dtype)) for l in leaves))
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        spec = (treedef, [(l.shape, l.dtype) for l in leaves])
        _SPEC_CACHE[key] = spec
    return spec


def flatten_pytree(params, spec=None):
    """Pytree -> (vector f32 [N], treedef+shapes for unflatten)."""
    if spec is None:
        spec = make_flatten_spec(params)
    leaves = jax.tree_util.tree_leaves(params)
    vec = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves]) \
        if leaves else jnp.zeros((0,), jnp.float32)
    return vec, spec


def flatten_batch(params_list, spec=None):
    """M pytrees of one config -> ([M, N] f32, spec) in a single batched
    flatten: per-leaf stack across models, one concatenate along N (replaces
    the per-model python re-flatten loop on the aggregation hot path)."""
    if spec is None:
        spec = make_flatten_spec(params_list[0])
    rows = [jax.tree_util.tree_leaves(p) for p in params_list]
    if not rows[0]:
        return jnp.zeros((len(rows), 0), jnp.float32), spec
    cols = [jnp.stack([jnp.ravel(r[i]).astype(jnp.float32) for r in rows])
            for i in range(len(rows[0]))]
    return jnp.concatenate(cols, axis=1), spec


def unflatten_pytree(vec, spec):
    treedef, shapes = spec
    leaves = []
    off = 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        leaves.append(jnp.reshape(vec[off:off + n], shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def unflatten_batch(mat, spec):
    """[K, N] f32 -> stacked pytree with leaves [K, *shape] (the batched
    inverse of ``flatten_batch``): one slice per leaf instead of K separate
    unflattens, so a whole round of models lands as one vmappable pytree."""
    treedef, shapes = spec
    K = mat.shape[0]
    leaves = []
    off = 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        leaves.append(jnp.reshape(mat[:, off:off + n],
                                  (K,) + tuple(shape)).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


def spec_length(spec) -> int:
    """True (unpadded) flattened length of a flatten spec's pytree."""
    _, shapes = spec
    return sum(int(np.prod(shape)) if shape else 1 for shape, _ in shapes)


# --------------------------------------------------------------------------- #
# MultiKRUM
# --------------------------------------------------------------------------- #

def pairwise_dists(x, force: str = "auto"):
    """x: [M, N] -> pairwise squared L2 [M, M]."""
    if force == "ref":
        return _ref.multikrum_dists(x)
    xp = _pad_to(x, 1, _mk.TILE_N)
    g, sq = _mk.gram_and_norms(xp, interpret=_interpret())
    d = sq + sq.T - 2.0 * g
    return jnp.maximum(d, 0.0)


def multikrum_scores(x, m: int, force: str = "auto"):
    """Sum of squared distances to the m nearest peers (lower = better)."""
    if force == "ref":
        return _ref.multikrum_scores(x, m)
    d = pairwise_dists(x, force)
    M = d.shape[0]
    d = d + jnp.diag(jnp.full((M,), jnp.inf))
    m = min(m, M - 1)
    return jnp.sum(jnp.sort(d, axis=1)[:, :m], axis=1)


# --------------------------------------------------------------------------- #
# Weighted aggregation
# --------------------------------------------------------------------------- #

def weighted_sum(x, w, force: str = "auto"):
    """x: [M, N], w: [M] -> [N]."""
    if force == "ref":
        return _ref.weighted_sum(x, w)
    N = x.shape[1]
    xp = _pad_to(x, 1, _ws.TILE_N)
    return _ws.weighted_sum(xp, w, interpret=_interpret())[:N]


# --------------------------------------------------------------------------- #
# Fused int8-native aggregation (quantized models never materialize as f32)
# --------------------------------------------------------------------------- #

QTILE = _q.TILE  # scale granularity of the quantized payload


def _pad_q8(q, scales):
    """Pad [M, Np] int8 + [M, Np/QTILE] scales to the kernel block width.
    Zero-padded q contributes nothing regardless of the padded scale."""
    return (_pad_to(q, q.ndim - 1, _q8.TILE_N),
            _pad_to(scales, scales.ndim - 1, _q8.QPB))


def weighted_sum_q8(q, scales, w, n: int = None, force: str = "auto"):
    """Fused dequantize + weighted sum. q: [M, Np] int8 (Np % QTILE == 0),
    scales: [M, Np/QTILE], w: [M] -> [n] f32 (n defaults to Np)."""
    M, Np = q.shape
    assert Np % QTILE == 0, f"quantized payload must be {QTILE}-aligned"
    n = Np if n is None else n
    if force == "ref":
        return _ref.wsum_q8(q, scales, w, QTILE)[:n]
    qp, sp = _pad_q8(q, scales)
    return _q8.wsum_q8(qp, sp, w, interpret=_interpret())[:n]


def add_q8_delta(base, q, scales, n: int = None, force: str = "auto"):
    """Fused delta-apply: base [n] f32 + dequantized int8 delta, one pass.
    q: [Np] int8 (Np % QTILE == 0), scales: [Np/QTILE] -> [n] f32 without
    materializing the f32 delta (n defaults to len(base))."""
    n = int(base.shape[0]) if n is None else n
    assert q.shape[0] % QTILE == 0, f"delta payload must be {QTILE}-aligned"
    if force == "ref":
        return _ref.add_q8_delta(base[:n], q, scales, QTILE)
    qp = _pad_to(q, 0, QUANT_BLOCK)
    sp = _pad_to(scales, 0, QUANT_BLOCK // QTILE)
    bp = jnp.pad(base[:n].astype(jnp.float32), (0, qp.shape[0] - n))
    return _q8.add_q8_delta(bp, qp, sp, interpret=_interpret())[:n]


def pairwise_dists_q8(q, scales, force: str = "auto"):
    """Fused dequantize + pairwise squared L2 of quantized models [M, M]."""
    if force == "ref":
        g, sq = _ref.gram_q8(q, scales, QTILE)
    else:
        qp, sp = _pad_q8(q, scales)
        g, sq = _q8.gram_q8(qp, sp, interpret=_interpret())
    d = sq + sq.T - 2.0 * g
    return jnp.maximum(d, 0.0)


def multikrum_scores_q8(q, scales, m: int, force: str = "auto"):
    """MultiKRUM scores straight off the int8 payloads (lower = better)."""
    d = pairwise_dists_q8(q, scales, force)
    M = d.shape[0]
    d = d + jnp.diag(jnp.full((M,), jnp.inf))
    m = min(m, M - 1)
    return jnp.sum(jnp.sort(d, axis=1)[:, :m], axis=1)


# --------------------------------------------------------------------------- #
# int8 compression
# --------------------------------------------------------------------------- #

QUANT_BLOCK = _q.TILE * _q.LANE


def quantize(x, force: str = "auto"):
    """x: [N] -> (q int8 [Np], scales [Np/TILE], N) with Np padded."""
    N = x.shape[0]
    if force == "ref":
        xp = _pad_to(x, 0, _q.TILE)
        q, s = _ref.quantize_int8(xp, _q.TILE)
        return q, s, N
    xp = _pad_to(x, 0, QUANT_BLOCK)
    q, s = _q.quantize(xp, interpret=_interpret())
    return q, s, N


def dequantize(q, scales, n, dtype=jnp.float32, force: str = "auto"):
    if force == "ref":
        return _ref.dequantize_int8(q, scales, _q.TILE)[:n].astype(dtype)
    return _q.dequantize(q, scales, dtype=dtype, interpret=_interpret())[:n]


def dequantize_batch(q, scales, n, dtype=jnp.float32, force: str = "auto"):
    """Batched dequant: q [K, Np] int8 + scales [K, Np/QTILE] -> [K, n] f32
    in ONE kernel pass (oracle: ``ref.dequantize_rows``). The scoring
    engine's q8-direct ingest: a round's packed payloads become one stacked
    matrix without K per-model dequant dispatches."""
    if force == "ref":
        return _ref.dequantize_rows(q, scales, _q.TILE)[:, :n].astype(dtype)
    return _q.dequantize_batch(q, scales, dtype=dtype,
                               interpret=_interpret())[:, :n]


# --------------------------------------------------------------------------- #
# WKV6
# --------------------------------------------------------------------------- #

def wkv6(r, k, v, w, u, state, force: str = "auto"):
    """r,k,v,w: [B, T, H, hs]; u: [H, hs]; state: [B, H, hs, hs]."""
    if force == "ref":
        return _ref.wkv6_naive(r, k, v, w, u, state)
    B, T, H, hs = r.shape
    fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, T, hs)
    rt, kt, vt = fold(r), fold(k), fold(v)
    wt = fold(w)
    pad = (-T) % _rwkv.CHUNK
    if pad:
        z = lambda a, cv=0.0: jnp.pad(a, ((0, 0), (0, pad), (0, 0)),
                                      constant_values=cv)
        rt, kt, vt, wt = z(rt), z(kt), z(vt), z(wt, 1.0)
    ub = jnp.broadcast_to(u, (B, H, hs)).reshape(B * H, hs)
    y, s1 = _rwkv.wkv6(rt, kt, vt, wt, ub, state.reshape(B * H, hs, hs),
                       interpret=_interpret())
    y = y[:, :T].reshape(B, H, T, hs).transpose(0, 2, 1, 3)
    return y, s1.reshape(B, H, hs, hs)
