"""Pallas kernels: int8-native fused aggregation (quantized exchange hot path).

The cross-silo round moves M peer models of flattened length N as int8
payloads (symmetric per-tile quantization, ``kernels/quant.py``). The seed
pipeline dequantized them to f32 and only then ran the weighted-sum /
MultiKRUM-Gram kernels — one extra f32 materialization of the whole [M, N]
set, 4x the HBM traffic of the int8 bytes that actually arrived.

These kernels consume the packed int8 blocks plus their per-tile scales
directly, fusing dequantization into the accumulation:

  wsum_q8:  out[n]  = sum_m w[m] * s[m, n // QT] * q[m, n]
            The per-tile scale folds into the weight vector, so the MXU
            contraction runs straight off the int8 block in VMEM.
  gram_q8:  G[i, j] = sum_n (s q)[i, n] * (s q)[j, n]
            Per quant tile, q @ q.T is an int8 x int8 -> int32 MXU matmul
            (exact: |sum| <= 127^2 * QT < 2^31); scales apply once per
            [M, M] tile as the rank-1 factor s s^T.

HBM traffic per round drops from (1 + 4 + 4) * M * N bytes (read int8, write
f32, re-read f32) to ~1.004 * M * N (int8 + scales), one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import quant as _q

QT = _q.TILE          # quantization tile (scale granularity), 1024
QPB = 4               # quant tiles per VMEM block
TILE_N = QPB * QT     # kernel block width along N
LANE = _q.LANE        # quant tiles per VMEM block in the quantizer layout


def _wsum_kernel(w_ref, q_ref, s_ref, o_ref):
    """w_ref: [1, M] f32; q_ref: [M, TILE_N] int8; s_ref: [M, QPB] f32."""
    w = w_ref[0, :]
    for k in range(QPB):
        ws = (w * s_ref[:, k])[None, :]                      # [1, M]
        qf = q_ref[:, k * QT:(k + 1) * QT].astype(jnp.float32)
        o_ref[:, k * QT:(k + 1) * QT] = jax.lax.dot_general(
            ws, qf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wsum_q8(q, scales, w, *, interpret: bool = False):
    """q: [M, N] int8 (N % TILE_N == 0); scales: [M, N/QT]; w: [M] -> [N] f32."""
    M, N = q.shape
    assert N % TILE_N == 0, f"pad N to a multiple of {TILE_N}"
    assert scales.shape == (M, N // QT), scales.shape
    grid = (N // TILE_N,)
    out = pl.pallas_call(
        _wsum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, M), lambda i: (0, 0)),
                  pl.BlockSpec((M, TILE_N), lambda i: (0, i)),
                  pl.BlockSpec((M, QPB), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, TILE_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32)[None, :], q, scales)
    return out[0]


def _add_delta_kernel(b_ref, q_ref, s_ref, o_ref):
    """b_ref/o_ref: [LANE, QT] f32; q_ref: [LANE, QT] int8; s_ref: [LANE, 1].
    Dequantization fuses into the add: the f32 delta never hits HBM."""
    o_ref[...] = b_ref[...] + q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def add_q8_delta(base, q, scales, *, interpret: bool = False):
    """base: [N] f32; q: [N] int8 delta (N % (QT*LANE) == 0);
    scales: [N/QT] f32 -> [N] f32 = base + dequantized delta, one pass."""
    N = q.shape[0]
    assert N % (QT * LANE) == 0, f"pad N to a multiple of {QT * LANE}"
    assert base.shape == (N,) and scales.shape == (N // QT,)
    rows = N // QT
    grid = (rows // LANE,)
    out = pl.pallas_call(
        _add_delta_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((LANE, QT), lambda i: (i, 0)),
                  pl.BlockSpec((LANE, QT), lambda i: (i, 0)),
                  pl.BlockSpec((LANE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((LANE, QT), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, QT), jnp.float32),
        interpret=interpret,
    )(base.astype(jnp.float32).reshape(rows, QT), q.reshape(rows, QT),
      scales[:, None])
    return out.reshape(-1)


def _gram_kernel(q_ref, s_ref, g_ref, sq_ref):
    """q_ref: [M, TILE_N] int8; s_ref: [M, QPB]; accumulates G [M,M], sq [M,1]."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    for k in range(QPB):
        qi = q_ref[:, k * QT:(k + 1) * QT]
        s = s_ref[:, k:k + 1]                                # [M, 1]
        # int8 x int8 -> int32 contraction over one quant tile is exact
        gq = jax.lax.dot_general(
            qi, qi, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        g_ref[...] += (s * s.T) * gq
        qsq = jnp.sum(qi.astype(jnp.int32) * qi.astype(jnp.int32),
                      axis=1, keepdims=True).astype(jnp.float32)
        sq_ref[...] += (s * s) * qsq


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram_q8(q, scales, *, interpret: bool = False):
    """q: [M, N] int8 (N % TILE_N == 0); scales: [M, N/QT]
    -> (G [M, M] f32, sq [M, 1] f32) of the dequantized models."""
    M, N = q.shape
    assert N % TILE_N == 0, f"pad N to a multiple of {TILE_N}"
    assert scales.shape == (M, N // QT), scales.shape
    grid = (N // TILE_N,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((M, TILE_N), lambda i: (0, i)),
                  pl.BlockSpec((M, QPB), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((M, M), lambda i: (0, 0)),
                   pl.BlockSpec((M, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, M), jnp.float32),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(q, scales)
