"""HLO-text statistics for the roofline analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so with
scan-over-layers it undercounts FLOPs/bytes by the trip count (verified
empirically in this container). This module parses the *partitioned,
scheduled* ``compiled.as_text()`` module instead:

  - builds a per-computation name -> shape table (scheduled HLO does not
    inline operand shapes),
  - extracts per-op output/operand shapes (PER-DEVICE after SPMD
    partitioning), dot/conv FLOPs, and collective bytes,
  - recovers while-loop trip counts from the loop condition's comparison
    constant and multiplies nested computations accordingly,
  - aggregates executed totals: FLOPs, an HBM-traffic proxy (operand+output
    bytes of scheduled top-level ops = fusion boundary traffic), and
    per-collective bytes with alpha-beta cost factors (all-reduce 2x ring).

Everything is per-device; roofline terms divide by per-chip peaks.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# leading output type(s): f32[1,2]{...} or tuple (f32[..], s32[..])
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
                        r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _prod(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


@dataclass
class Op:
    name: str
    opcode: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    line: str
    flops: float = 0.0
    collective: Optional[str] = None
    called: List[str] = field(default_factory=list)
    trip_count: Optional[int] = None

    @property
    def out_bytes(self) -> int:
        return sum(_prod(s) * DTYPE_BYTES.get(d, 4) for d, s in self.out_shapes)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = field(default_factory=dict)
    max_constant: int = 0

    def operand_bytes(self, op: Op) -> int:
        total = 0
        for o in op.operands:
            for d, s in self.shapes.get(o, []):
                total += _prod(s) * DTYPE_BYTES.get(d, 4)
        return total


def _out_shapes_of(rest: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Shapes before the opcode '(' — the op's output type (maybe a tuple)."""
    paren = rest.find("(")
    # tuple outputs start with '(': find the opcode position instead
    m = _OPCODE_RE.match(rest)
    cut = rest.index(m.group(1) + "(") if m else (paren if paren >= 0 else len(rest))
    head = rest[:cut]
    return [( d, tuple(int(x) for x in dims.split(",")) if dims else () )
            for d, dims in _SHAPE_RE.findall(head)]


def _args_of(rest: str) -> List[str]:
    m = _OPCODE_RE.match(rest)
    if not m:
        return []
    start = rest.index(m.group(1) + "(") + len(m.group(1)) + 1
    depth, i = 1, start
    while i < len(rest) and depth:
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
        i += 1
    return _OPERAND_RE.findall(rest[start:i - 1])


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        header = None
        if not line.startswith(" ") and line.endswith("{") and "->" in line:
            header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
        if header:
            cur = Computation(header.group(2))
            comps[cur.name] = cur
            if header.group(1):
                entry = cur.name
            continue
        if cur is None or line.strip() == "}":
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        out_shapes = _out_shapes_of(rest)
        if not out_shapes and "parameter(" not in rest:
            continue
        opm = _OPCODE_RE.match(rest)
        opcode = opm.group(1) if opm else (
            "parameter" if "parameter(" in rest else "")
        cm = re.search(r"constant\((\d+)\)", rest)
        if cm:
            cur.max_constant = max(cur.max_constant, int(cm.group(1)))
        cur.shapes[name] = out_shapes
        if opcode in ("", "parameter", "constant"):
            continue
        op = Op(name, opcode, out_shapes, _args_of(rest), rest)
        if opcode == "dot":
            op.flops = 0.0  # filled after shapes table is complete
        for coll in COLLECTIVES:
            if opcode.startswith(coll):
                op.collective = coll
        if opcode == "while":
            bm = re.search(r"body=%?([\w.\-]+)", rest)
            cm2 = re.search(r"condition=%?([\w.\-]+)", rest)
            if bm and cm2:
                op.called = [bm.group(1), cm2.group(1)]
            # XLA annotates known trip counts in backend_config — exact.
            tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
            if tm:
                op.trip_count = int(tm.group(1))
        elif opcode in ("fusion", "call", "conditional", "custom-call"):
            for cm3 in re.finditer(r"(?:calls|to_apply|body|branch_computations=\{)"
                                   r"=?%?([\w.\-]+)", rest):
                op.called.append(cm3.group(1))
        cur.ops.append(op)
    # second pass: dot/conv flops now that operand shapes are known
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "dot":
                op.flops = _dot_flops(op, comp)
            elif op.opcode == "convolution":
                op.flops = _conv_flops(op, comp)
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    out_n = sum(_prod(s) for _, s in op.out_shapes)
    lhs = comp.shapes.get(op.operands[0], []) if op.operands else []
    if not lhs:
        return 0.0
    lhs_shape = lhs[0][1]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    k = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_shape):
                k *= lhs_shape[idx]
    return 2.0 * out_n * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_n = sum(_prod(s) for _, s in op.out_shapes)
    if len(op.operands) < 2:
        return 0.0
    ker = comp.shapes.get(op.operands[1], [])
    if not ker:
        return 0.0
    ker_n = _prod(ker[0][1])
    out_shape = op.out_shapes[0][1]
    oc = out_shape[-1] if out_shape else 1
    return 2.0 * out_n * max(1, ker_n // max(1, oc))


def compute_multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for _ in range(64):
        changed = False
        for cname, cmult in list(mult.items()):
            comp = comps.get(cname)
            if comp is None or cmult <= 0:
                continue
            for op in comp.ops:
                if not op.called:
                    continue
                if op.opcode == "while":
                    body, cond = op.called
                    trips = op.trip_count if op.trip_count else (
                        max(1, comps[cond].max_constant) if cond in comps else 1)
                    subs = ((body, trips), (cond, trips + 1))
                else:
                    subs = tuple((s, 1) for s in op.called)
                for sub, k in subs:
                    new = cmult * k
                    if mult[sub] < new:
                        mult[sub] = new
                        changed = True
        if not changed:
            break
    return dict(mult)


@dataclass
class HloStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_cost_bytes: float = 0.0
    collective_count: int = 0
    flops_unscaled: float = 0.0
    top_collectives: List = field(default_factory=list)

    def to_dict(self):
        return {"flops": self.flops, "traffic_bytes": self.traffic_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_cost_bytes": self.collective_cost_bytes,
                "collective_count": self.collective_count,
                "flops_unscaled": self.flops_unscaled,
                "top_collectives": self.top_collectives[:20]}


_COLL_FACTOR = {"all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_NO_TRAFFIC = {"tuple", "get-tuple-element", "parameter", "bitcast",
               "constant", "while", "after-all", "partition-id", "replica-id"}


def analyze(text: str) -> HloStats:
    comps, entry = parse_module(text)
    if not entry and comps:
        entry = max(comps, key=lambda c: len(comps[c].ops))
    mult = compute_multipliers(comps, entry)
    st = HloStats()
    colls = []
    # computations reached through fusions contribute flops but their
    # interior ops are not HBM traffic (fused); track which are fusion-only
    fusion_called = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fusion_called.update(op.called)
    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        inside_fusion = cname in fusion_called
        for op in comp.ops:
            st.flops_unscaled += op.flops
            if k <= 0:
                continue
            st.flops += op.flops * k
            if op.collective:
                b = max(op.out_bytes, comp.operand_bytes(op))
                st.collective_bytes[op.collective] = \
                    st.collective_bytes.get(op.collective, 0.0) + b * k
                st.collective_cost_bytes += b * k * _COLL_FACTOR[op.collective]
                st.collective_count += int(k)
                colls.append((b * k, op.collective, op.name, int(k)))
            if not inside_fusion and op.opcode not in _NO_TRAFFIC:
                st.traffic_bytes += (op.out_bytes + comp.operand_bytes(op)) * k
    colls.sort(reverse=True)
    st.top_collectives = [{"bytes_total": b, "kind": kd, "op": nm, "times": t}
                          for b, kd, nm, t in colls[:20]]
    return st
