"""ShapeDtypeStruct stand-ins for every model input of every cell.

``input_specs(arch, shape_name, multi_pod)`` returns (kwargs, in_shardings)
for the step function of that cell — no device allocation, weak-type-correct,
shardable. Used by launch/dryrun.py and benchmarks/roofline.py.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import pshard
from repro.config import ModelConfig, ShapeConfig, shapes_for
from repro.configs import get_config
from repro.models import build_model
from repro.models.encdec import src_len

SDS = jax.ShapeDtypeStruct


def batch_axes(global_batch: int, mesh, multi_pod: bool):
    """Which mesh axes the batch dim shards over (per-pod batch when
    multi_pod: the leading stack dim takes 'pod')."""
    data = mesh.shape.get("data", 1)
    return ("data",) if global_batch % data == 0 and global_batch >= data else ()


def _ns(mesh, *spec):
    with pshard.use_mesh(mesh):
        return NamedSharding(mesh, pshard.resolve_spec(*spec))


def _stack(tree, p: int):
    return jax.tree.map(lambda s: SDS((p,) + tuple(s.shape), s.dtype), tree)


def _stack_shardings(shardings, mesh):
    def one(ns):
        spec = ns.spec if ns is not None else P()
        return NamedSharding(mesh, P("pod", *spec))
    return jax.tree.map(one, shardings,
                        is_leaf=lambda x: isinstance(x, NamedSharding))


def param_specs(model, cfg: ModelConfig, mesh):
    """Abstract params + their NamedShardings under ``mesh``."""
    with pshard.use_mesh(mesh):
        params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shardings = pshard.param_shardings(params_sds, model.param_rules())
    return params_sds, shardings


def _batch_axis(B: int, mesh):
    """Largest prefix of the configured batch axes that divides B."""
    axes = tuple(a for a in pshard.get_batch_axes()
                 if a in mesh.axis_names and a != "pod")
    while axes:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if B % n == 0 and B >= n:
            break
        axes = axes[:-1]
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                per_pod_batch: Optional[int] = None):
    """Train/prefill batch SDS + shardings (without any pod stacking)."""
    B = per_pod_batch or shape.global_batch
    S = shape.seq_len
    b_ax = _batch_axis(B, mesh)
    toks = SDS((B, S), jnp.int32)
    batch = {"tokens": toks, "targets": toks}
    sh = {"tokens": _ns(mesh, b_ax, None), "targets": _ns(mesh, b_ax, None)}
    if cfg.family == "encdec":
        batch["frames"] = SDS((B, src_len(S), cfg.d_model), jnp.float32)
        sh["frames"] = _ns(mesh, b_ax, None, None)
    return batch, sh


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, model, *,
                 per_pod_batch: Optional[int] = None):
    B = per_pod_batch or shape.global_batch
    b_ax = "data" if B % mesh.shape.get("data", 1) == 0 and B >= mesh.shape.get("data", 1) else None
    batch = {"token": SDS((B,), jnp.int32), "pos": SDS((), jnp.int32)}
    bsh = {"token": _ns(mesh, b_ax), "pos": _ns(mesh)}
    with pshard.use_mesh(mesh):
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, shape.seq_len))
        cache_spec = model.cache_spec(B)
        csh = jax.tree.map(
            lambda s, sds: NamedSharding(mesh, pshard.size_filter(s, sds.shape)),
            cache_spec, cache_sds, is_leaf=lambda x: isinstance(x, P))
    return batch, bsh, cache_sds, csh


def input_specs(arch: str, shape_name: str = "train_4k", *,
                multi_pod: bool = False, mesh=None,
                sharding: Optional[str] = None) -> Dict:
    """Everything dryrun needs for one cell: kwargs + in_shardings for the
    step function appropriate to the cell kind."""
    import dataclasses
    from repro.launch.mesh import make_production_mesh
    cfg = get_config(arch)
    if sharding:
        cfg = dataclasses.replace(cfg, sharding_mode=sharding)
    pshard.set_batch_axes(("pod", "data", "model")
                          if cfg.sharding_mode in ("fsdp", "dp")
                          else ("pod", "data"))
    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    if shape.kind != "train" and cfg.fsdp and sharding is None:
        # serve-time sharding != train-time sharding: FSDP param all-gathers
        # cost ~params bytes PER TOKEN in decode; drop the data-axis shard
        # whenever the TP-sharded params fit HBM (<= ~12 GB/chip bf16)
        if cfg.n_params() * 2 / 16 <= 12e9:
            cfg = dataclasses.replace(cfg, fsdp=False)
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    n_pods = mesh.shape.get("pod", 1)
    params_sds, psh = param_specs(model, cfg, mesh)

    out = {"cfg": cfg, "shape": shape, "mesh": mesh, "model": model,
           "kind": shape.kind, "multi_pod": multi_pod}
    if shape.kind in ("train", "prefill"):
        per_pod = shape.global_batch // n_pods if multi_pod else None
        if multi_pod and shape.global_batch % n_pods:
            per_pod = max(1, shape.global_batch // n_pods)
        batch_sds, bsh = batch_specs(cfg, shape, mesh, per_pod_batch=per_pod)
        if multi_pod:
            params_sds = _stack(params_sds, n_pods)
            psh = _stack_shardings(psh, mesh)
            batch_sds = _stack(batch_sds, n_pods)
            bsh = _stack_shardings(bsh, mesh)
        out.update(kwargs={"params": params_sds, "batch": batch_sds},
                   in_shardings=(psh, bsh))
    else:  # decode
        per_pod = None
        if multi_pod:
            per_pod = max(1, shape.global_batch // n_pods)
        batch_sds, bsh, cache_sds, csh = decode_specs(
            cfg, shape, mesh, model, per_pod_batch=per_pod)
        if multi_pod:
            params_sds = _stack(params_sds, n_pods)
            psh = _stack_shardings(psh, mesh)
            batch_sds = _stack(batch_sds, n_pods)
            bsh = _stack_shardings(bsh, mesh)
            cache_sds = _stack(cache_sds, n_pods)
            csh = _stack_shardings(csh, mesh)
        out.update(kwargs={"params": params_sds, "batch": batch_sds,
                           "cache": cache_sds},
                   in_shardings=(psh, bsh, csh))
    return out
