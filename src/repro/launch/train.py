"""End-to-end UnifyFL training driver.

Two modes:
  - image: the paper's CIFAR-like workload (CNN, Dirichlet-NIID silos)
  - lm:    federated LM pretraining over per-silo Markov dialects, for any
           assigned architecture via --arch (reduced preset trains a small
           same-family config on this CPU host; full preset is the real
           config for TPU pods).

Examples:
  PYTHONPATH=src python -m repro.launch.train --workload image --mode sync \
      --rounds 10 --silos 3
  PYTHONPATH=src python -m repro.launch.train --workload lm --arch qwen3-1.7b \
      --preset smoke --rounds 5 --mode async --policy top_k
"""
from __future__ import annotations

import argparse
import json
import time

from repro.config import FedConfig, replace
from repro.configs import get_config, get_smoke_config
from repro.core.builder import (SiloSpec, build_image_experiment,
                                build_lm_experiment, global_eval)
from repro.core.orchestrator import SiloPolicy


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--workload", choices=["image", "lm"], default="image")
    p.add_argument("--arch", default="paper-cnn")
    p.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    p.add_argument("--mode", choices=["sync", "async"], default="sync")
    p.add_argument("--rounds", type=int, default=5)
    p.add_argument("--silos", type=int, default=3)
    p.add_argument("--clients", type=int, default=2)
    p.add_argument("--local-epochs", type=int, default=1)
    p.add_argument("--policy", default="all")
    p.add_argument("--score-policy", default="median")
    p.add_argument("--scorer", default="accuracy")
    p.add_argument("--k", type=int, default=2)
    p.add_argument("--partition", choices=["iid", "niid"], default="niid")
    p.add_argument("--alpha", type=float, default=0.5)
    p.add_argument("--compression",
                   choices=["none", "int8", "int8-delta", "topk-delta"],
                   default="none")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)

    fed = FedConfig(n_silos=args.silos, clients_per_silo=args.clients,
                    rounds=args.rounds, local_epochs=args.local_epochs,
                    mode=args.mode, scorer=args.scorer,
                    agg_policy=args.policy, score_policy=args.score_policy,
                    policy_k=args.k, compression=args.compression)
    t0 = time.time()
    if args.workload == "image":
        cfg = get_config("paper-cnn")
        orch = build_image_experiment(cfg, fed, partition=args.partition,
                                      alpha=args.alpha, seed=args.seed)
    else:
        cfg = (get_smoke_config(args.arch) if args.preset == "smoke"
               else get_config(args.arch))
        orch = build_lm_experiment(cfg, fed, seed=args.seed)
    print(f"workload={args.workload} arch={cfg.arch_id} mode={fed.mode} "
          f"silos={fed.n_silos}x{fed.clients_per_silo} rounds={fed.rounds} "
          f"policy={fed.agg_policy}/{fed.score_policy}")
    orch.run(args.rounds)
    ge = global_eval(orch)
    wall = time.time() - t0
    print(f"\nfinished in {wall:.1f}s wall / {orch.env.now:.1f}s simulated")
    print(f"ledger: {orch.ledger.height} blocks, "
          f"{orch.ledger.stats['txs']} txs, verify={orch.ledger.verify()}")
    for sid, m in ge.items():
        print(f"  {sid}: global acc={m['accuracy']:.4f} loss={m['loss']:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"global_eval": ge, "summary": orch.summary(),
                       "sim_time": orch.env.now, "wall": wall}, f, indent=1,
                      default=str)
    return ge


if __name__ == "__main__":
    main()
