import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: jax locks the device count on first init.
# The dry-run (and only the dry-run) builds the 512-chip production meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) 'data','model' or (2,16,16)
     'pod','data','model'),
  2. materializes ShapeDtypeStruct inputs (launch/specs.py — no allocation),
  3. jits the cell's step function with explicit in_shardings,
  4. .lower().compile() — a sharding mismatch, compile-time OOM, or
     unsupported collective here is a bug in the framework,
  5. prints compiled.memory_analysis() (fits-per-device proof) and
     cost_analysis(), parses the partitioned HLO for trip-count-adjusted
     FLOPs / HBM traffic / per-collective bytes (launch/hlostats.py),
  6. emits a JSON record consumed by benchmarks/roofline.py and
     EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import pshard
from repro.config import shapes_for
from repro.configs import get_config, list_archs
from repro.core.exchange import (ExchangeConfig, make_pod_serve_step,
                                 make_train_step, make_unifyfl_round_step)
from repro.launch import hlostats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12   # bf16
HBM_BW = 819e9        # bytes/s
LINK_BW = 50e9        # bytes/s/link ICI


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / n_devices


def build_step(si, ex_cfg: ExchangeConfig, lr: float = 0.01):
    """Returns (fn, donate) for the cell described by input_specs output."""
    model, mesh, kind, multi_pod = si["model"], si["mesh"], si["kind"], si["multi_pod"]
    if kind == "train":
        if multi_pod:
            return make_unifyfl_round_step(model, mesh, ex_cfg, lr), (0,)
        return make_train_step(model, lr), (0,)
    if kind == "prefill":
        if multi_pod:
            return make_pod_serve_step(model, mesh, "prefill"), ()
        return (lambda params, batch: model.prefill(params, batch)), ()
    # decode
    if multi_pod:
        step = make_pod_serve_step(model, mesh, "decode")
        return (lambda params, batch, cache: step(params, batch, cache)), (2,)
    return (lambda params, batch, cache:
            model.decode_step(params, batch, cache)), (2,)


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             ex_policy: str = "top_k", compression: str = "none",
             mesh_shape=None, sharding=None, scorer: str = "loss",
             verbose: bool = True) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    si = input_specs(arch, shape_name, multi_pod=multi_pod, mesh=mesh,
                     sharding=sharding)
    cfg, shape = si["cfg"], si["shape"]
    ex_cfg = ExchangeConfig(policy=ex_policy, compression=compression,
                            scorer=scorer)
    fn, donate = build_step(si, ex_cfg)
    kwargs = si["kwargs"]
    order = ["params", "batch", "cache"]
    args = [kwargs[k] for k in order if k in kwargs]
    in_sh = si["in_shardings"]
    with pshard.use_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    st = hlostats.analyze(txt)
    n_dev = mesh.size
    mf = model_flops_per_device(cfg, shape, n_dev)
    compute_s = st.flops / PEAK_FLOPS
    memory_s = st.traffic_bytes / HBM_BW
    coll_s = st.collective_cost_bytes / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    rec = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "n_devices": n_dev,
        "policy": ex_policy if (multi_pod and shape.kind == "train") else None,
        "compression": compression if multi_pod else None,
        "params_total": cfg.n_params(),
        "params_active": cfg.n_active_params(),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", -1),
        },
        "cost_analysis": {"flops": cost.get("flops", -1.0),
                          "bytes_accessed": cost.get("bytes accessed", -1.0)},
        "hlo": st.to_dict(),
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_per_dev": mf,
            "useful_flops_ratio": (mf / st.flops) if st.flops > 0 else 0.0,
            "roofline_frac": (mf / PEAK_FLOPS) / max(
                compute_s, memory_s, coll_s) if max(
                compute_s, memory_s, coll_s) > 0 else 0.0,
        },
        "compile_wall_s": time.time() - t0,
    }
    if verbose:
        ma = rec["memory_analysis"]
        print(f"[{arch} x {shape_name} x {rec['mesh']}] OK "
              f"compile={rec['compile_wall_s']:.1f}s")
        print(f"  memory_analysis: args={ma['argument_bytes']/1e9:.3f}GB "
              f"out={ma['output_bytes']/1e9:.3f}GB temp={ma['temp_bytes']/1e9:.3f}GB "
              f"(per device)")
        print(f"  hlo/dev: flops={st.flops:.3e} traffic={st.traffic_bytes:.3e}B "
              f"coll={st.collective_cost_bytes:.3e}B ({st.collective_count} ops)")
        print(f"  roofline terms (s): compute={compute_s:.4f} "
              f"memory={memory_s:.4f} collective={coll_s:.4f} "
              f"-> dominant={dominant} frac={rec['roofline']['roofline_frac']:.3f}")
    return rec


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--policy", default="top_k")
    p.add_argument("--compression", default="none")
    p.add_argument("--sharding", default=None,
                   help="override cfg.sharding_mode: tp | fsdp | dp")
    p.add_argument("--scorer", default="loss")
    p.add_argument("--dev", action="store_true",
                   help="reduced dev meshes (2,4)/(2,2,4) for fast iteration")
    p.add_argument("--force", action="store_true")
    p.add_argument("--subprocess", action="store_true",
                   help="run each cell in its own process (XLA CHECK-failure "
                        "crashes abort the process; this isolates them)")
    args = p.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in shapes_for(cfg)]
        if args.shape:
            shapes = [args.shape]
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[{tag}] cached, skipping")
                    continue
                if args.subprocess:
                    import subprocess
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape_name,
                           "--mesh", "multi" if mp else "single",
                           "--out", args.out, "--policy", args.policy,
                           "--compression", args.compression]
                    if args.dev:
                        cmd.append("--dev")
                    if args.force:
                        cmd.append("--force")
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    sys.stdout.write(r.stdout)
                    if r.returncode != 0:
                        failures.append((tag, f"exit {r.returncode}"))
                        print(f"[{tag}] FAILED (subprocess exit {r.returncode})")
                        sys.stdout.write(r.stderr[-2000:])
                    continue
                try:
                    mesh_shape = ((2, 2, 4) if mp else (2, 4)) if args.dev else None
                    rec = run_cell(arch, shape_name, mp,
                                   ex_policy=args.policy,
                                   compression=args.compression,
                                   mesh_shape=mesh_shape,
                                   sharding=args.sharding,
                                   scorer=args.scorer)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[{tag}] FAILED: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        sys.exit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
