"""Production mesh construction.

Importing this module never touches jax device state; both helpers are
functions. The production topology is a v5e-class pod of 16x16 = 256 chips;
multi-pod doubles it with a leading 'pod' (= UnifyFL silo) axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         shape: Optional[Tuple[int, ...]] = None):
    """(16,16) 'data','model' single pod; (2,16,16) 'pod','data','model'
    multi-pod. ``shape`` overrides sizes for reduced dev runs (axis names
    keep the same layout semantics)."""
    if multi_pod:
        shape = shape or (2, 16, 16)
        axes = ("pod", "data", "model")
    else:
        shape = shape or (16, 16)
        axes = ("data", "model")
    # jax < 0.5 has neither jax.sharding.AxisType nor the axis_types kwarg;
    # Auto is the default there, so only pass it when the API exists.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(shape))
