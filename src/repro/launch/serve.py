"""Batched serving driver: prefill + decode loop for any assigned arch.

Serves the (reduced-preset) model with batched requests — continuous
batched greedy decoding with a KV cache/state. On TPU the same code path
serves the full configs (see launch/dryrun.py for the compile proof of the
prefill_32k / decode_32k / long_500k cells).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
      --preset smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.models.encdec import src_len


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-1.7b")
    p.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.preset == "smoke"
           else get_config(args.arch))
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": prompts, "targets": prompts}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, src_len(S), cfg.d_model))

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    # pad the cache to prompt+gen for the attention families
    cache_full = model.init_cache(B, S + args.gen)
    cache = jax.tree.map(
        lambda full, got: jax.lax.dynamic_update_slice(
            full, got.astype(full.dtype), (0,) * full.ndim)
        if full.shape != got.shape else got, cache_full, cache)

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits_i, cache = decode(params, {"token": tok,
                                          "pos": jnp.int32(S + i)}, cache)
        tok = jnp.argmax(logits_i, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.arch_id} batch={B} prompt={S} gen={gen.shape[1]}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms total, "
          f"{t_decode/max(1,args.gen-1)*1e3:.2f} ms/token/batch "
          f"({B*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample generated ids:", gen[0, :12].tolist())
    assert np.all(np.isfinite(np.asarray(logits_i))), "non-finite logits"
    return gen


if __name__ == "__main__":
    main()
