"""EdgeFleet: the simulated edge population behind one silo.

The paper's multilevel comparison (hierarchical FL) puts device-grade
participants *under* each silo-grade participant: edge clients hold small
Dirichlet shards of the silo's data, train locally, and FedAvg up at the
silo before the silo enters the cross-silo round. ``EdgeFleet`` is that
tier as a first-class subsystem instead of the old ``hbfl.py`` strawman:

  * **partial participation** — each round samples
    ``ceil(participation * N)`` clients with a deterministic per-(silo,
    round) RNG;
  * **heterogeneous devices** — every client carries a device profile
    (``devices.py``); its simulated train time is profile-drawn, and the
    fleet's round time is the *slowest sampled device* (devices run in
    parallel, the silo waits for the last upload);
  * **charged traffic** — model down (silo -> edge) and update up
    (edge -> silo) move on the fabric as kind ``"edge"`` transfers, so a
    fleet's fan-in hammers the silo's *access port* under the fair-share
    model exactly like a thousand silos hammer the orchestrator's;
  * **aggregation** — sampled results FedAvg by sample count through the
    same kernel-backed ``fedavg_params`` the cross-silo tier uses
    (``fedavg_up``); clients whose shard is smaller than one batch are
    skipped (``stats['skipped_empty']``) — with hundreds of clients per
    silo, Dirichlet shards legitimately go sub-batch.

``traffic_round`` drives the sampling + charging + delay model without any
ML — the synthetic path ``edgebench`` sweeps at 10/100/1000 clients per
silo. With ``fabric=None`` transfers are free and only device delays count
(the Table 1/5 baselines run fabric-less).
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.edge.devices import assign_profile, train_delay_s
from repro.fed.aggregator import fedavg_params
from repro.obs.metrics import StatsView


def fedavg_up(results: Sequence[Tuple]) -> Optional[object]:
    """Sample-weighted FedAvg of ``[(params, n_samples, ...), ...]`` — the
    one aggregation-up step shared by the edge tier and the hbfl baseline
    (a single trusted top-level aggregator is the same operation with
    silos as the participants)."""
    results = [r for r in results if r[1] > 0]
    if not results:
        return None
    return fedavg_params([r[0] for r in results],
                         [float(r[1]) for r in results])


class EdgeFleet:
    def __init__(self, silo_id: str, clients: List, *,
                 participation: float = 1.0, epochs: int = 1,
                 seed: int = 0):
        if not clients:
            raise ValueError(f"{silo_id}: an edge fleet needs clients")
        self.silo_id = silo_id
        self.clients = clients
        self.participation = float(participation)
        self.epochs = int(epochs)
        self.seed = int(seed)
        self.profiles = [assign_profile(silo_id, j, seed)
                         for j in range(len(clients))]
        self.stats = StatsView("edge", silo_id)
        self.fabric = None
        self.env = None
        self.round = 0
        self.last_participants: List[int] = []
        self._model_nbytes = 0

    # -- wiring -------------------------------------------------------------- #
    def attach(self, fabric=None, env=None) -> None:
        """Late-bind the fabric/engine (the orchestrator owns both); edge
        node ids register so transfers and access ports resolve."""
        self.fabric = fabric
        self.env = env
        if fabric is not None:
            for nid in self.node_ids:
                fabric.register_node(nid)

    @property
    def node_ids(self) -> List[str]:
        return [c.client_id for c in self.clients]

    # -- sampling ------------------------------------------------------------- #
    def sample(self, rnd: int) -> List[int]:
        """Deterministic partial-participation draw for round ``rnd``."""
        n = max(1, round(self.participation * len(self.clients)))
        rng = random.Random(f"edge|{self.silo_id}|{rnd}|{self.seed}")
        return sorted(rng.sample(range(len(self.clients)), n))

    # -- traffic + delay model ------------------------------------------------ #
    def _model_bytes(self, params) -> int:
        if self._model_nbytes == 0:
            import jax
            self._model_nbytes = int(sum(
                p.size * p.dtype.itemsize
                for p in jax.tree.leaves(params)))
        return self._model_nbytes

    def traffic_round(self, rnd: int, nbytes: int
                      ) -> Tuple[float, int, List[int]]:
        """Charge one round of fleet traffic (no ML): global model down to
        every sampled client, update up from each — kind ``"edge"``, both
        directions through the silo's access port — plus device train
        delays. Returns ``(sim_seconds, total_bytes, reachable_indices)``
        where sim_seconds is the slowest sampled device's down+train+up
        path (devices run in parallel)."""
        idxs = self.sample(rnd)
        rng = random.Random(f"edgedelay|{self.silo_id}|{rnd}|{self.seed}")
        slowest, total, reachable = 0.0, 0, []
        for j in idxs:
            delay = train_delay_s(self.profiles[j], self.epochs, rng)
            down_s = up_s = 0.0
            nid = self.clients[j].client_id
            if self.fabric is not None:
                from repro.net.fabric import UnreachableError
                try:
                    down_s = self.fabric.transfer(
                        self.silo_id, nid, f"edge:down:r{rnd}", nbytes,
                        kind="edge")
                    up_s = self.fabric.transfer(
                        nid, self.silo_id, f"edge:up:r{rnd}", nbytes,
                        kind="edge")
                except UnreachableError:
                    continue        # silo partitioned from its own fleet
            total += 2 * nbytes
            reachable.append(j)
            slowest = max(slowest, down_s + delay + up_s)
            self.stats["train_s"] += delay
        self.stats["rounds"] += 1
        self.stats["participants"] += len(reachable)
        self.stats["bytes_down"] += nbytes * len(reachable)
        self.stats["bytes_up"] += nbytes * len(reachable)
        self.last_participants = reachable
        return slowest, total, reachable

    # -- the edge tier round --------------------------------------------------- #
    def train_round(self, params, *, local_epochs: Optional[int] = None
                    ) -> Tuple[object, Dict]:
        """One fleet round: sample, charge traffic, train each sampled
        client locally, FedAvg up by sample count. Returns
        ``(aggregated_params, metrics)`` — params unchanged when nothing
        trained (all sampled shards sub-batch or unreachable)."""
        nbytes = self._model_bytes(params)
        sim_s, total_bytes, idxs = self.traffic_round(self.round, nbytes)
        epochs = self.epochs if local_epochs is None else local_epochs
        results, losses, skipped = [], [], 0
        for j in idxs:
            c = self.clients[j]
            if c.n_samples < c.batch_size:
                skipped += 1        # shard too small for one batch: no step
                continue
            r = c.local_train(params, epochs)
            results.append(r)
            losses.append(r[2])
        self.stats["skipped_empty"] += skipped
        agg = fedavg_up(results)
        if self.env is not None:
            from repro.obs import events as obsev
            self.env.emit(obsev.edge_round(self.silo_id, self.round,
                                           len(idxs), total_bytes))
        metrics = {
            "edge_participants": len(idxs),
            "edge_trained": len(results),
            "edge_skipped": skipped,
            "edge_sim_s": sim_s,
            "edge_bytes": total_bytes,
            "client_loss": float(sum(losses) / len(losses)) if losses
            else 0.0,
        }
        self.round += 1
        return (agg if agg is not None else params), metrics
