"""Edge device profiles: where heterogeneous train delays come from.

The paper's edge workload (Table 6) mixes Raspberry Pi and Jetson class
devices; an edge fleet is never uniform. Each simulated edge client is
assigned one named profile — deterministically, from a sha256 draw over
``(silo, index, seed)`` like the topology's link-tier assignment — and its
per-round training delay is ``base + epochs * per_epoch + U(0, jitter)``
simulated seconds, with the jitter drawn from the caller's seeded RNG so
runs are bit-reproducible.

Profiles are *simulated-clock* costs only: the actual gradient math runs
on the host at full speed (same convention as ``time_scale`` for silo
compute).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    base_s: float        # fixed per-round overhead (wakeup, load, serialize)
    per_epoch_s: float   # marginal cost of one local epoch
    jitter_s: float      # uniform jitter bound (thermal / scheduling noise)


DEVICE_PROFILES: Dict[str, DeviceProfile] = {
    "rpi4": DeviceProfile("rpi4", base_s=2.4, per_epoch_s=1.1,
                          jitter_s=0.6),
    "jetson-nano": DeviceProfile("jetson-nano", base_s=0.9, per_epoch_s=0.4,
                                 jitter_s=0.25),
    "laptop": DeviceProfile("laptop", base_s=0.3, per_epoch_s=0.12,
                            jitter_s=0.08),
}

# fleet mix: (profile, cumulative weight) — ~50% rpi4, 30% jetson, 20% laptop
_MIX: Tuple[Tuple[str, int], ...] = (("rpi4", 5), ("jetson-nano", 8),
                                     ("laptop", 10))


def assign_profile(silo_id: str, index: int, seed: int = 0) -> DeviceProfile:
    """Deterministic profile draw for edge client ``index`` of ``silo_id``."""
    h = hashlib.sha256(f"edge:{seed}:{silo_id}:{index}".encode()).digest()
    draw = int.from_bytes(h[:8], "big") % _MIX[-1][1]
    for name, cum in _MIX:
        if draw < cum:
            return DEVICE_PROFILES[name]
    return DEVICE_PROFILES[_MIX[-1][0]]


def train_delay_s(profile: DeviceProfile, epochs: int, rng) -> float:
    """One round's simulated training time on this device."""
    jitter = rng.uniform(0.0, profile.jitter_s) if profile.jitter_s else 0.0
    return profile.base_s + epochs * profile.per_epoch_s + jitter
