"""repro.edge — the hierarchical edge tier behind every silo.

The paper's multilevel-FL comparison made concrete: ``EdgeFleet`` manages
N simulated edge clients per silo (partial participation, Dirichlet data
shards, heterogeneous device-profile train delays) that train locally and
FedAvg up at the silo before the cross-silo round; edge<->silo traffic is
charged on the fabric's access ports (kind ``"edge"``), and edge nodes can
follow the chain as light clients (``repro.chain.light``) instead of full
replicas. Configured entirely through ``FedConfig.edge_per_silo`` /
``edge_participation`` / ``edge_epochs`` / ``edge_light_clients``.

devices -- named device profiles (rpi4 / jetson-nano / laptop) +
           deterministic assignment and per-round delay draws
fleet   -- EdgeFleet (sampling, charged traffic, FedAvg-up) and
           ``fedavg_up``, the aggregation step shared with fed/hbfl.py
"""
from repro.edge.devices import (DEVICE_PROFILES, DeviceProfile,
                                assign_profile, train_delay_s)
from repro.edge.fleet import EdgeFleet, fedavg_up

__all__ = ["EdgeFleet", "fedavg_up", "DeviceProfile", "DEVICE_PROFILES",
           "assign_profile", "train_delay_s"]
