"""Mesh-aware sharding helpers.

Models annotate activations with *logical* axis specs; ``constrain`` resolves
them against the currently-installed mesh, dropping axes the mesh doesn't have
(so the same model code runs on a single CPU device, a (data, model) pod, or a
(pod, data, model) multi-pod mesh).
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None
_MANUAL: tuple = ()  # axes currently inside a shard_map manual region

# jax < 0.5 can't express "Manual subgroup" constraint meshes (no AxisType);
# emitting constraints inside a partial-manual shard_map region there trips
# an XLA CHECK (IsManualSubgroup). Constraints are layout hints, so they are
# simply skipped in manual regions on those versions.
_HAS_AXISTYPE = hasattr(jax.sharding, "AxisType")

# Logical batch axis: models constrain batch dims with the BATCH sentinel;
# 'tp' sharding resolves it to ('pod','data'), 'fsdp' to
# ('pod','data','model') (pure ZeRO-3: both axes act data-parallel).
BATCH = "__batch__"
_BATCH_AXES: tuple = ("pod", "data")


def set_batch_axes(axes) -> None:
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def get_batch_axes() -> tuple:
    return _BATCH_AXES


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


@contextlib.contextmanager
def manual_axes(axes):
    """Mark mesh axes as shard_map-manual: constraints drop them."""
    global _MANUAL
    prev, _MANUAL = _MANUAL, tuple(axes)
    try:
        yield
    finally:
        _MANUAL = prev


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _MESH
    prev, _MESH = _MESH, mesh
    try:
        yield
    finally:
        _MESH = prev


def _filter_axis(axis, names):
    if axis is None:
        return None
    if axis == BATCH:
        axis = _BATCH_AXES
    if isinstance(axis, (tuple, list)):
        kept = tuple(a for a in axis if a in names)
        return kept if len(kept) > 1 else (kept[0] if kept else None)
    return axis if axis in names else None


def resolve_spec(*spec) -> P:
    """Drop spec axes that the installed mesh doesn't provide (or that are
    currently shard_map-manual). A mesh axis may appear once: the first
    occurrence wins (e.g. fsdp batch = ('data','model') nulls a later
    'model' head constraint)."""
    names = _MESH.axis_names if _MESH is not None else ()
    names = tuple(n for n in names if n not in _MANUAL)
    used: set = set()
    out = []
    for a in spec:
        f = _filter_axis(a, names)
        if f is None:
            out.append(None)
            continue
        fs = f if isinstance(f, tuple) else (f,)
        kept = tuple(x for x in fs if x not in used)
        used.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def _constraint_mesh():
    """Inside a shard_map manual region the constraint's mesh must carry the
    Manual axis types (JAX validates context mesh == sharding mesh)."""
    if not _MANUAL:
        return _MESH
    try:
        from jax.sharding import AxisType
        return _MESH.abstract_mesh.update_axis_types(
            {a: AxisType.Manual for a in _MANUAL if a in _MESH.axis_names})
    except Exception:
        return _MESH


def _axis_size(ax) -> int:
    if ax is None or _MESH is None:
        return 1
    axes = ax if isinstance(ax, (tuple, list)) else (ax,)
    sizes = dict(_MESH.shape)  # works for Mesh and AbstractMesh
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def size_filter(spec: P, shape) -> P:
    """Drop spec axes whose mesh size doesn't divide the dim (jit
    in_shardings require exact divisibility; e.g. 8 or 36 heads vs model=16)."""
    out = []
    for i, ax in enumerate(spec):
        if i >= len(shape) or ax is None:
            out.append(ax if i < len(shape) else None)
            continue
        n = _axis_size(ax)
        out.append(ax if (n > 0 and shape[i] % n == 0 and shape[i] >= n) else None)
    return P(*out)


def constrain(x, *spec):
    """with_sharding_constraint against the installed mesh (no-op if none)."""
    if _MESH is None or len(_MESH.axis_names) == 0:
        return x
    if _MANUAL and not _HAS_AXISTYPE:
        return x
    resolved = size_filter(resolve_spec(*spec), x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_constraint_mesh(), resolved))


def named_sharding(*spec) -> Optional[NamedSharding]:
    if _MESH is None:
        return None
    return NamedSharding(_MESH, resolve_spec(*spec))


# --------------------------------------------------------------------------- #
# Rule-based parameter sharding
# --------------------------------------------------------------------------- #

def spec_for_param(path: str, shape, rules) -> P:
    """First regex rule matching ``path`` wins; rules map pattern -> spec
    tuple. Axes that don't divide the dim are dropped (size_filter)."""
    for pat, spec in rules:
        if re.search(pat, path):
            cleaned = []
            for i, ax in enumerate(spec):
                if ax is None or i >= len(shape):
                    cleaned.append(None)
                    continue
                cleaned.append(ax)
            return size_filter(resolve_spec(*cleaned[: len(shape)]), shape)
    return resolve_spec(*([None] * len(shape)))


def tree_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params, rules):
    """Pytree of NamedSharding for a param pytree, by path-regex rules."""
    def one(path, leaf):
        if _MESH is None or (_MANUAL and not _HAS_AXISTYPE):
            return None
        spec = spec_for_param(tree_path_str(path), leaf.shape, rules)
        return NamedSharding(_MESH, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def param_specs(params, rules):
    """Pytree of PartitionSpec (mesh-filtered) for a param pytree."""
    def one(path, leaf):
        return spec_for_param(tree_path_str(path), leaf.shape, rules)
    return jax.tree_util.tree_map_with_path(one, params)
