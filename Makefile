# The tier-1 verify invocation lives here and nowhere else: CI, the docs and
# humans all run `make verify`. PYTEST_ARGS appends (e.g. -m "not slow").
PYTHON ?= python
PYTEST_ARGS ?=

.PHONY: verify netbench scalebench kernelbench scorebench chainbench \
	trustbench recoverybench edgebench trace

verify:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q $(PYTEST_ARGS)

netbench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.netbench --quick

# Thousand-silo scale sweep only (batched vs reference engine, fair-share
# fabric): reruns the sweep and merges the "scale" section into BENCH_net.json
scalebench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.netbench --quick --scale

kernelbench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.kernelbench

scorebench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.scorebench --quick

chainbench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.chainbench --quick

# Adversarial trust scenarios only (colluding scorers, sealer slashing +
# governance eviction, reputation recovery): merges the "trust" section
# into BENCH_chain.json
trustbench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.chainbench --quick --trust-only

recoverybench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.recoverybench --quick

# Hierarchical edge tier: the 10/100/1000 clients-per-silo fleet sweep
# (merged into BENCH_net.json as "edge") and the 3-tier light-client run
# (merged into BENCH_chain.json as "light", acceptance: light sync <= 10%
# of full block-replay bytes)
edgebench:
	PYTHONPATH=src $(PYTHON) -m benchmarks.edgebench --quick

# Obs-enabled traced run: exports trace.json (Chrome trace-event JSON —
# load it at https://ui.perfetto.dev), validates it, prints the run report.
trace:
	PYTHONPATH=src $(PYTHON) -m benchmarks.netbench --quick --trace-only \
		--trace trace.json
	PYTHONPATH=src $(PYTHON) -m repro.obs.report trace.json --validate
	PYTHONPATH=src $(PYTHON) -m repro.obs.report trace.json
